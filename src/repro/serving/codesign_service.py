"""Co-design as a service: one micro-batched, compile-cached front door.

PRs 1-5 built five scoring/co-design entry points; every consumer (CLIs,
benchmarks, notebooks) called them directly, re-deriving populations and
re-tracing jit graphs per call.  ``CodesignService`` is the serving front
door over the SAME kernels:

  * **Micro-batching** -- concurrent score/sweep requests over different
    profile suites are admitted into ONE struct-of-arrays pass: the app
    axis of the batched kernels is already batched, so compatible
    requests' suites are concatenated (``ProfileBatch.concat``), scored
    by a single ``run_sweep`` call over the shared population, and
    scattered back per request (``SweepResult.app_slice``).  The kernels
    are app-rowwise independent, so each scattered result is
    byte-identical to a direct ``run_sweep`` for that request alone
    (pinned in tests/test_serving.py).
  * **Compile/artifact caching** -- populations are cached by
    (space, n, mode, seed, named-seed) signature in a byte-bounded LRU
    (``pop_cache_bytes``) so repeat queries skip generation without a
    mega-request pinning unbounded RAM; artifact keys
    ``(population shape, backend, constraint
    signature)`` are tracked so same-shape queries reuse the backend's
    jitted kernels instead of re-tracing; byte-identical repeat requests
    hit a result memo and skip everything.  Frontier queries warm-start
    from cached continuation state at the nearest already-solved budget
    (``frontier_codesign(warm_theta=...)``).
  * **Async job queue** -- bounded worker threads behind a thread-safe
    submit/poll/stream API.  Overload is a 429-style
    ``ServiceOverloadError`` at submit (never a hang); per-request
    timeouts expire jobs at dispatch and between mega-sweep shards;
    mega-sweep requests stream shard-by-shard progress events; responses
    render through the uniform result protocol (``markdown``/``to_json``)
    only.

The service runs requests exactly as the library would -- every cache is
an economy, never a semantic change, except the frontier warm start
(``CodesignRequest(warm=False)`` opts out) which seeds the descent from
solved state and is allowed to land at a better optimum.

Walkthrough: docs/serving.md.  Load test: ``python benchmarks/run.py
codesign_service``.  CLI: ``python -m repro.launch.serve_codesign``.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import threading
import time
from typing import Any, Dict, Iterator, List, Mapping, Optional, Sequence

import numpy as np

from repro.core.costmodel import DEFAULT_COST_MODEL
from repro.core.machine import VARIANTS
from repro.core.spec import CodesignSpec
from repro.core.sweep import (
    MachineBatch,
    ParamSpace,
    ProfileBatch,
    _as_profile_batch,
    _population,
    _resolve_beta,
    run_sweep,
    shard_sweep,
)

#: Request kinds and the library entry point each one fronts.
KINDS = ("sweep", "mega_sweep", "constrained", "joint", "frontier", "pack",
         "bilevel")

#: Job lifecycle states (terminal: done/error/cancelled/timeout/rejected).
PENDING, RUNNING = "pending", "running"
DONE, ERROR, CANCELLED, TIMEOUT = "done", "error", "cancelled", "timeout"
TERMINAL = (DONE, ERROR, CANCELLED, TIMEOUT)


class ServiceOverloadError(RuntimeError):
    """Submit-time rejection when the pending queue is full (429-style:
    the caller sees an immediate, retryable error -- never a hang)."""

    status_code = 429


class JobCancelled(RuntimeError):
    pass


class JobTimeout(TimeoutError):
    pass


class _AbortRun(Exception):
    """Raised inside a progress callback to stop a sharded run early
    (cancellation or deadline) -- shard_sweep unwinds between shards."""

    def __init__(self, state: str):
        self.state = state


# --------------------------------------------------------------------------- #
# Request signatures (cache keys)
# --------------------------------------------------------------------------- #


def _canon(obj) -> Any:
    """Canonical, hash-stable structure for any request component."""
    if obj is None or isinstance(obj, (str, int, float, bool)):
        return obj
    if isinstance(obj, np.ndarray):
        return ("nd", obj.shape, str(obj.dtype),
                hashlib.blake2b(np.ascontiguousarray(obj).tobytes(),
                                digest_size=16).hexdigest())
    if isinstance(obj, Mapping):
        return tuple(sorted((str(k), _canon(v)) for k, v in obj.items()))
    if isinstance(obj, (list, tuple)):
        return tuple(_canon(v) for v in obj)
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return (type(obj).__name__,
                tuple((f.name, _canon(getattr(obj, f.name)))
                      for f in dataclasses.fields(obj)))
    return repr(obj)


def _sig(*parts) -> str:
    return hashlib.blake2b(repr(tuple(_canon(p) for p in parts)).encode(),
                           digest_size=16).hexdigest()


# --------------------------------------------------------------------------- #
# Requests and jobs
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class CodesignRequest:
    """One unified request: a profile suite plus a ``CodesignSpec``.

    ``kind`` picks the entry point; the spec carries budgets, envelopes,
    the frontier schedule, descent knobs and the backend.  ``machines``
    (co-design kinds) defaults to the paper's named variants; ``space``
    (sweep kinds) defaults to ``ParamSpace.default()``.

    ``profiles`` may be a model-zoo suite name (``"zoo"``,
    ``"zoo-smoke:train"``, ...) -- or ``None``, in which case
    ``spec.suite`` must name the suite (validated by the ONE
    ``CodesignSpec.validate`` path); either way the name is resolved
    against the zoo cache at execution time by ``_as_profile_batch``.
    """

    kind: str
    profiles: Any                       # suite, ProfileBatch, or joint groups
    spec: CodesignSpec = dataclasses.field(default_factory=CodesignSpec)
    machines: Any = None                # co-design seeds
    space: Optional[ParamSpace] = None  # sweep design space
    include_named: Sequence = ()
    beta_machine: Any = None
    num_shards: Optional[int] = None    # mega_sweep
    keep_top: int = 16                  # mega_sweep pre-filter width
    timeout: Optional[float] = None     # seconds, queue wait included
    warm: bool = True                   # frontier: allow cache warm start
    stream: bool = False                # mega_sweep: regenerate per shard
    checkpoint_dir: Optional[str] = None  # mega_sweep: resumable state
    resume: bool = False                # mega_sweep: skip completed shards

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown request kind {self.kind!r}; "
                             f"have {KINDS}")
        self.spec.validate()
        if self.profiles is None:
            if self.spec.suite is None:
                raise ValueError(
                    "profiles is required unless spec.suite names a "
                    "model-zoo suite (e.g. CodesignSpec(suite='zoo-smoke'))")
            self.profiles = self.spec.suite

    # -- resolved sweep parameters (spec field > historical default) ----- #

    def _sweep_params(self) -> Dict[str, Any]:
        s = self.spec
        return dict(
            n=(1024 if self.kind == "mega_sweep" else 256)
              if s.n is None else s.n,
            mode="random" if s.sweep_mode is None else s.sweep_mode,
            seed=0 if s.seed is None else s.seed,
            timing_model="serial" if s.timing_model is None
                         else s.timing_model,
            clamp=True if s.clamp is None else s.clamp,
            backend=s.backend,
        )

    def batch_key(self) -> Optional[str]:
        """Micro-batch compatibility: requests sharing this key score the
        same population under the same kernel configuration, so their
        suites may ride one SoA pass.  Per-request beta targets are
        resolved into per-app vectors and concatenated, so they do NOT
        constrain compatibility."""
        if self.kind != "sweep":
            return None
        p = self._sweep_params()
        return _sig("batch", self.space, p["n"], p["mode"], p["seed"],
                    self.include_named, self.beta_machine,
                    p["timing_model"], p["clamp"], p["backend"])

    def memo_key(self) -> str:
        """Exact-request identity: byte-identical repeats share a result."""
        return _sig("memo", self.kind, self.profiles, self.spec,
                    self.machines, self.space, self.include_named,
                    self.beta_machine, self.num_shards, self.keep_top,
                    self.warm, self.stream, self.checkpoint_dir,
                    self.resume)


@dataclasses.dataclass
class Job:
    jid: str
    request: CodesignRequest
    state: str = PENDING
    result: Any = None
    error: Optional[BaseException] = None
    events: List[dict] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    cancel_requested: bool = False
    cache: Optional[str] = None        # None | "memo" | "warm"

    @property
    def deadline(self) -> Optional[float]:
        t = self.request.timeout
        return None if t is None else self.submitted_at + t

    def snapshot(self) -> dict:
        """poll() view: plain data, no live references."""
        return {
            "jid": self.jid,
            "kind": self.request.kind,
            "state": self.state,
            "events": len(self.events),
            "cache": self.cache,
            "queued_s": ((self.started_at or time.monotonic())
                         - self.submitted_at),
            "run_s": (None if self.started_at is None else
                      (self.finished_at or time.monotonic())
                      - self.started_at),
        }


# --------------------------------------------------------------------------- #
# The service
# --------------------------------------------------------------------------- #


class CodesignService:
    """Thread-safe scoring/co-design front door (see module docstring).

    ``workers=0`` (or ``auto_start=False``) runs no threads: callers
    drive the queue synchronously with ``process_once()``/``drain()`` --
    the exact worker code path, used by the deterministic tests.
    """

    def __init__(self, *, workers: int = 2, max_pending: int = 64,
                 auto_start: bool = True,
                 pop_cache_bytes: int = 256 << 20):
        if max_pending < 1:
            raise ValueError("max_pending must be >= 1")
        self._cond = threading.Condition()
        self._queue: collections.deque = collections.deque()
        self._jobs: Dict[str, Job] = {}
        self._next_id = 0
        self._stop = False
        self.max_pending = max_pending
        # caches -----------------------------------------------------------
        # population cache: LRU bounded by ``pop_cache_bytes`` (a 100M-
        # variant request must never pin ~7 GB of arrays forever; entries
        # larger than the whole budget are served but not cached)
        self._populations: "collections.OrderedDict[str, MachineBatch]" = \
            collections.OrderedDict()
        self.pop_cache_bytes = int(pop_cache_bytes)
        self._pop_bytes = 0
        self._memo: Dict[str, Any] = {}
        self._frontier_state: Dict[str, dict] = {}
        self._artifacts: Dict[str, int] = {}
        # accounting -------------------------------------------------------
        self.stats = collections.Counter()
        # workers ----------------------------------------------------------
        self._threads: List[threading.Thread] = []
        if auto_start and workers > 0:
            for i in range(workers):
                t = threading.Thread(target=self._worker_loop,
                                     name=f"codesign-worker-{i}", daemon=True)
                t.start()
                self._threads.append(t)

    # ------------------------------ client API ------------------------- #

    def submit(self, request: CodesignRequest) -> str:
        """Enqueue a request; returns a job id.

        Raises ``ServiceOverloadError`` (``status_code == 429``) when the
        pending queue is at ``max_pending`` -- overload is an immediate,
        retryable rejection, never a hang."""
        with self._cond:
            if self._stop:
                raise RuntimeError("service is shut down")
            if len(self._queue) >= self.max_pending:
                self.stats["rejected"] += 1
                raise ServiceOverloadError(
                    f"pending queue full ({self.max_pending}); retry later")
            self._next_id += 1
            job = Job(jid=f"job-{self._next_id}", request=request,
                      submitted_at=time.monotonic())
            self._jobs[job.jid] = job
            self._queue.append(job)
            self.stats["submitted"] += 1
            self._cond.notify_all()
            return job.jid

    def poll(self, jid: str) -> dict:
        with self._cond:
            return self._jobs[jid].snapshot()

    def result(self, jid: str, timeout: Optional[float] = None):
        """Block until the job is terminal and return its result.

        Raises the job's own error, ``JobCancelled``, ``JobTimeout`` (job
        expired), or ``TimeoutError`` (this wait expired -- the job keeps
        running)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            job = self._jobs[jid]
            while job.state not in TERMINAL:
                wait = (None if deadline is None
                        else max(deadline - time.monotonic(), 0.0))
                if wait == 0.0:
                    raise TimeoutError(f"result({jid!r}) wait expired")
                self._cond.wait(timeout=wait if wait is None else
                                min(wait, 0.1))
            if job.state == DONE:
                return job.result
            if job.state == CANCELLED:
                raise JobCancelled(jid)
            if job.state == TIMEOUT:
                raise JobTimeout(jid)
            raise job.error

    def cancel(self, jid: str) -> bool:
        """Cancel a job.  Pending jobs die immediately; a running
        mega-sweep aborts at its next shard boundary; other running kinds
        finish their compute but report ``cancelled`` and discard the
        result."""
        with self._cond:
            job = self._jobs[jid]
            if job.state in TERMINAL:
                return False
            job.cancel_requested = True
            if job.state == PENDING:
                try:
                    self._queue.remove(job)
                except ValueError:
                    pass
                self._finish(job, CANCELLED)
            return True

    def stream(self, jid: str, poll_s: float = 0.02) -> Iterator[dict]:
        """Yield a job's progress events as they arrive, ending with one
        terminal event (``done``/``error``/``cancelled``/``timeout``) --
        the generator always terminates once the job does."""
        seen = 0
        while True:
            with self._cond:
                job = self._jobs[jid]
                while seen >= len(job.events) and job.state not in TERMINAL:
                    self._cond.wait(timeout=poll_s)
                fresh = list(job.events[seen:])
                state = job.state
            seen += len(fresh)
            for ev in fresh:
                yield ev
            if state in TERMINAL and seen >= len(self._jobs[jid].events):
                yield {"event": state, "jid": jid}
                return

    def render(self, jid: str, fmt: str = "markdown",
               top_k: Optional[int] = None,
               timeout: Optional[float] = None):
        """Render a finished job through the uniform result protocol.

        Dispatches ONLY on ``markdown(top_k=...)`` / ``to_json(top_k=...)``
        -- every sweep/co-design result type implements both, so the
        service needs exactly one renderer per format."""
        result = self.result(jid, timeout=timeout)
        return render_result(result, fmt=fmt, top_k=top_k)

    def shutdown(self, wait: bool = True) -> None:
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        if wait:
            for t in self._threads:
                t.join(timeout=5.0)

    # ------------------------- synchronous driving ---------------------- #

    def process_once(self) -> bool:
        """Dequeue and run one job (plus any micro-batch riders) on the
        calling thread; returns False when the queue is empty.  This is
        the worker loop body -- tests drive it for determinism."""
        with self._cond:
            job = self._dequeue()
        if job is None:
            return False
        self._execute(job)
        return True

    def drain(self) -> None:
        while self.process_once():
            pass

    # ----------------------------- internals ---------------------------- #

    def _worker_loop(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._stop:
                    self._cond.wait(timeout=0.1)
                if self._stop and not self._queue:
                    return
                job = self._dequeue()
            if job is not None:
                self._execute(job)

    def _dequeue(self) -> Optional[Job]:
        """Pop the oldest pending job; expire it instead if its deadline
        already passed (graceful degradation: late jobs cost nothing)."""
        while self._queue:
            job = self._queue.popleft()
            if job.deadline is not None and time.monotonic() > job.deadline:
                self._finish(job, TIMEOUT)
                continue
            job.state = RUNNING
            job.started_at = time.monotonic()
            return job
        return None

    def _finish(self, job: Job, state: str, result=None, error=None) -> None:
        """Caller must hold (or not need) consistency: always locks."""
        job.state = state
        job.result = result
        job.error = error
        job.finished_at = time.monotonic()
        self.stats[state] += 1
        self._cond.notify_all()

    def _complete(self, job: Job, result) -> None:
        with self._cond:
            if job.cancel_requested:
                self._finish(job, CANCELLED)
            elif (job.deadline is not None
                  and time.monotonic() > job.deadline):
                self._finish(job, TIMEOUT)
            else:
                self._finish(job, DONE, result=result)

    def _fail(self, job: Job, exc: BaseException) -> None:
        with self._cond:
            if isinstance(exc, _AbortRun):
                self._finish(job, exc.state)
            else:
                self._finish(job, ERROR, error=exc)

    # -- execution -------------------------------------------------------- #

    def _execute(self, job: Job) -> None:
        req = job.request
        memo_key = req.memo_key()
        with self._cond:
            if memo_key in self._memo:
                self.stats["memo_hits"] += 1
                job.cache = "memo"
                job.events.append({"event": "cached", "jid": job.jid})
                self._finish(job, DONE, result=self._memo[memo_key])
                return
            self.stats["memo_misses"] += 1
            riders = (self._claim_riders(job)
                      if req.kind == "sweep" else [])
        group = [job] + riders
        try:
            if req.kind == "sweep":
                self._run_sweep_group(group)
                return
            runner = {
                "mega_sweep": self._run_mega_sweep,
                "constrained": self._run_constrained,
                "joint": self._run_joint,
                "frontier": self._run_frontier,
                "pack": self._run_pack,
                "bilevel": self._run_bilevel,
            }[req.kind]
            result = runner(job)
        except BaseException as exc:      # noqa: BLE001 -- jobs never crash workers
            self._fail(job, exc)
            return
        with self._cond:
            self._memo.setdefault(memo_key, result)
        self._complete(job, result)

    def _claim_riders(self, job: Job) -> List[Job]:
        """Pull every still-pending sweep job compatible with ``job`` out
        of the queue (micro-batch admission).  Lock held by caller."""
        key = job.request.batch_key()
        riders = []
        for other in list(self._queue):
            if other.request.kind != "sweep":
                continue
            if other.request.batch_key() != key:
                continue
            if (other.deadline is not None
                    and time.monotonic() > other.deadline):
                continue
            self._queue.remove(other)
            other.state = RUNNING
            other.started_at = time.monotonic()
            riders.append(other)
        return riders

    # -- sweeps ----------------------------------------------------------- #

    @staticmethod
    def _pop_nbytes(pop: MachineBatch) -> int:
        from repro.core.sweep import SWEEP_PARAMS

        return (sum(getattr(pop, f).nbytes for f in SWEEP_PARAMS)
                + sum(len(n) for n in pop.names))

    def _population_for(self, space: ParamSpace, n: int, mode: str,
                        seed: int, include_named) -> MachineBatch:
        key = _sig("pop", space, n, mode, seed, include_named)
        with self._cond:
            pop = self._populations.get(key)
            if pop is not None:
                self._populations.move_to_end(key)
                self.stats["pop_hits"] += 1
                return pop
            self.stats["pop_misses"] += 1
        pop = _population(space, n, mode, seed, list(include_named))
        with self._cond:
            cached = self._populations.get(key)
            if cached is not None:  # another worker raced us to it
                self._populations.move_to_end(key)
                return cached
            size = self._pop_nbytes(pop)
            if size <= self.pop_cache_bytes:
                self._populations[key] = pop
                self._pop_bytes += size
                while (self._pop_bytes > self.pop_cache_bytes
                       and len(self._populations) > 1):
                    _, old = self._populations.popitem(last=False)
                    self._pop_bytes -= self._pop_nbytes(old)
                    self.stats["pop_evictions"] += 1
            else:
                self.stats["pop_uncacheable"] += 1
            return pop

    def _note_artifact(self, kind: str, shape, backend, constraint_sig) -> None:
        """Track the (population shape, backend, constraint signature)
        artifact key: a repeat key means the backend's jitted kernels (or
        the descent trace at that shape) are reused rather than re-traced."""
        key = _sig("artifact", kind, tuple(shape), str(backend),
                   constraint_sig)
        with self._cond:
            seen = self._artifacts.get(key, 0)
            self._artifacts[key] = seen + 1
            self.stats["artifact_hits" if seen else "artifact_misses"] += 1

    def _run_sweep_group(self, group: List[Job]) -> None:
        """ONE SoA pass for every job in ``group``: concatenate suites,
        score once over the shared (cached) population, scatter rows back.
        Kernel rows are per-app independent, so each slice is
        byte-identical to that request run alone (pinned in tests)."""
        lead = group[0].request
        p = lead._sweep_params()
        space = lead.space or ParamSpace.default()
        include_named = list(lead.include_named)
        try:
            pop = self._population_for(space, p["n"], p["mode"], p["seed"],
                                       include_named)
            pbs = [_as_profile_batch(j.request.profiles) for j in group]
            betas = [
                _resolve_beta(pb, j.request.spec.beta, lead.beta_machine,
                              include_named, space, p["backend"])
                for pb, j in zip(pbs, group)]
            suite = ProfileBatch.concat(*pbs) if len(pbs) > 1 else pbs[0]
            self._note_artifact(
                "sweep", (len(suite), len(pop)), p["backend"],
                _sig(p["timing_model"], p["clamp"]))
            full = run_sweep(
                suite, space=space, n=p["n"], mode=p["mode"], seed=p["seed"],
                include_named=include_named, beta=np.concatenate(betas),
                beta_machine=lead.beta_machine,
                timing_model=p["timing_model"], clamp=p["clamp"],
                backend=p["backend"], population=pop)
        except BaseException as exc:      # noqa: BLE001
            for job in group:
                self._fail(job, exc)
            return
        if len(group) > 1:
            self.stats["batched_groups"] += 1
            self.stats["batched_requests"] += len(group)
        lo = 0
        for job, pb in zip(group, pbs):
            hi = lo + len(pb)
            res = full.app_slice(range(lo, hi)) if len(group) > 1 else full
            lo = hi
            with self._cond:
                self._memo.setdefault(job.request.memo_key(), res)
            self._complete(job, res)

    def _run_mega_sweep(self, job: Job):
        req = job.request
        p = req._sweep_params()
        space = req.space or ParamSpace.default()
        spec = req.spec

        def progress(s, num_shards, lo, hi):
            with self._cond:
                if job.cancel_requested:
                    raise _AbortRun(CANCELLED)
                if (job.deadline is not None
                        and time.monotonic() > job.deadline):
                    raise _AbortRun(TIMEOUT)
                job.events.append({"event": "shard", "jid": job.jid,
                                   "shard": int(s),
                                   "num_shards": int(num_shards),
                                   "lo": int(lo), "hi": int(hi)})
                self._cond.notify_all()

        pb = _as_profile_batch(req.profiles)
        self._note_artifact("mega_sweep", (len(pb), p["n"]), p["backend"],
                            _sig(p["timing_model"], p["clamp"],
                                 req.num_shards, req.keep_top, req.stream))
        return shard_sweep(
            pb, space=space, n=p["n"], mode=p["mode"], seed=p["seed"],
            include_named=list(req.include_named), beta=spec.beta,
            beta_machine=req.beta_machine, timing_model=p["timing_model"],
            clamp=p["clamp"], backend=p["backend"],
            num_shards=req.num_shards, keep_top=req.keep_top,
            cost_model=spec.cost_model or DEFAULT_COST_MODEL,
            progress=progress, stream=req.stream,
            checkpoint_dir=req.checkpoint_dir, resume=req.resume)

    # -- co-design -------------------------------------------------------- #

    def _seeds(self, req: CodesignRequest):
        if req.machines is not None:
            return req.machines
        return MachineBatch.from_models(VARIANTS)

    def _constraint_sig(self, spec: CodesignSpec) -> str:
        return _sig(spec.area_budget, spec.power_budget, spec.area_envelope,
                    spec.mode, spec.projection, spec.optimize_links)

    def _run_constrained(self, job: Job):
        from repro.core.constrained import constrained_codesign

        req = job.request
        seeds = self._seeds(req)
        self._note_artifact("constrained", (len(seeds),), "jax",
                            self._constraint_sig(req.spec))
        return constrained_codesign(req.profiles, seeds, spec=req.spec)

    def _run_joint(self, job: Job):
        from repro.core.constrained import joint_codesign

        req = job.request
        seeds = self._seeds(req)
        self._note_artifact("joint", (len(seeds),), "jax",
                            self._constraint_sig(req.spec))
        return joint_codesign(req.profiles, seeds, spec=req.spec)

    def _run_pack(self, job: Job):
        from repro.core.packing import pack_codesign

        req = job.request
        seeds = self._seeds(req)
        spec = req.spec
        self._note_artifact(
            "pack", (len(seeds), spec.num_machines or 4), "jax",
            self._constraint_sig(spec))
        # ``PackingResult`` joins the response path purely through the
        # uniform markdown/to_json protocol -- render_result needs no
        # isinstance knowledge of it.
        return pack_codesign(req.profiles, seeds, spec=spec)

    def _run_bilevel(self, job: Job):
        from repro.core.implicit import bilevel_codesign

        req = job.request
        seeds = self._seeds(req)
        spec = req.spec
        if spec.total_budget is None:
            raise ValueError("kind='bilevel' needs spec.total_budget "
                             "(the budget split across area and power)")
        self._note_artifact(
            "bilevel", (len(seeds),), "jax",
            _sig(spec.total_budget, spec.split0, spec.outer_steps,
                 spec.area_envelope, spec.projection))
        # ``BilevelResult`` joins the response path purely through the
        # uniform markdown/to_json protocol, like pack does.
        return bilevel_codesign(req.profiles, seeds, spec=spec)

    def _run_frontier(self, job: Job):
        from repro.core.frontier import frontier_codesign

        req = job.request
        seeds = self._seeds(req)
        spec = req.spec
        if spec.budgets is None:
            raise ValueError("frontier requests need spec.budgets")
        # Continuation cache: keyed by everything EXCEPT the schedule, so
        # a new schedule over the same suite/seeds/constraints can resume
        # from the nearest already-solved budget instead of cold seeds.
        state_key = _sig("frontier", req.profiles, req.machines,
                         dataclasses.replace(spec, budgets=None),
                         req.include_named)
        warm_theta = warm_lr = None
        with self._cond:
            entry = self._frontier_state.get(state_key)
            warm_enabled = req.warm and (spec.warm_start is None
                                         or spec.warm_start)
            if entry and warm_enabled:
                loosest = max(float(b) for b in spec.budgets)
                solved = sorted(entry["thetas"])
                # nearest solved budget, preferring the tightest >= loosest
                ge = [b for b in solved if b >= loosest]
                pick = min(ge) if ge else max(solved)
                warm_theta = entry["thetas"][pick]
                warm_lr = entry["lr"]
                self.stats["frontier_warm_hits"] += 1
                job.cache = "warm"
            else:
                self.stats["frontier_warm_misses"] += 1
        self._note_artifact("frontier", (len(seeds),), "jax",
                            self._constraint_sig(spec))
        res = frontier_codesign(req.profiles, seeds, spec=spec,
                                warm_theta=warm_theta, warm_lr=warm_lr,
                                keep_state=True)
        with self._cond:
            entry = self._frontier_state.setdefault(
                state_key, {"thetas": {}, "lr": None})
            entry["thetas"].update(res.continuation or {})
            entry["lr"] = res.final_lr
        return res


# --------------------------------------------------------------------------- #
# Response renderers (uniform result protocol)
# --------------------------------------------------------------------------- #


def render_result(result, fmt: str = "markdown",
                  top_k: Optional[int] = None):
    """Render ANY sweep/co-design result: dispatches exclusively on the
    uniform protocol -- ``markdown(top_k=...)`` for fmt="markdown",
    ``to_json(top_k=...)`` for fmt="json".  No isinstance checks: a new
    result type joins the service by implementing the two methods.

    >>> class Fake:
    ...     def markdown(self, top_k=None): return f"md top_k={top_k}"
    ...     def to_json(self, top_k=None): return {"top_k": top_k}
    >>> render_result(Fake(), "markdown", top_k=3)
    'md top_k=3'
    >>> render_result(Fake(), "json")["top_k"] is None
    True
    >>> render_result(object())
    Traceback (most recent call last):
        ...
    TypeError: result type 'object' does not implement the result protocol (markdown/to_json)
    """
    if not (callable(getattr(result, "markdown", None))
            and callable(getattr(result, "to_json", None))):
        raise TypeError(
            f"result type {type(result).__name__!r} does not implement "
            "the result protocol (markdown/to_json)")
    if fmt == "markdown":
        return result.markdown(top_k=top_k)
    if fmt == "json":
        return result.to_json(top_k=top_k)
    raise ValueError(f"unknown render format {fmt!r}; have "
                     "('markdown', 'json')")
