"""Serving: prefill/decode steps and a batched continuous-batching scheduler.

``make_serve_step(cfg)`` returns the one-token decode step used by the
``decode_*`` / ``long_*`` dry-run shapes: given a KV cache covering
``seq_len`` context, decode exactly one new token per sequence.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig


def make_serve_step(cfg: ModelConfig):
    """serve_step(params, cache, tokens (B,1), index) -> (cache, next_tokens)."""

    def serve_step(params, cache, tokens, index):
        cache, logits = T.decode_step(params, cfg, cache, tokens, index)
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return cache, next_tokens

    return serve_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, cache, batch):
        cache, logits = T.prefill(params, cfg, batch, cache)
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return cache, next_tokens

    return prefill_step


# --------------------------------------------------------------------------- #
# Minimal continuous-batching engine (CPU-scale example driver)
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class BatchedEngine:
    """Fixed-slot continuous batching: finished requests release their slot,
    waiting requests are admitted, all slots decode in lockstep (the standard
    serving dataflow, scaled down to run on CPU in the examples)."""

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 max_len: int = 256):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.cache, _ = T.init_cache(cfg, slots, max_len)
        self.active: Dict[int, Request] = {}
        self.slot_of: Dict[int, int] = {}
        self.free = list(range(slots))
        self.pos = [0] * slots
        self.queue: List[Request] = []
        self._decode = jax.jit(make_serve_step(cfg))

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        while self.queue and self.free:
            req = self.queue.pop(0)
            slot = self.free.pop(0)
            self.active[req.rid] = req
            self.slot_of[req.rid] = slot
            # prefill this slot token-by-token (keeps one decode code path);
            # an empty prompt is padded with token 0 so there is always a
            # last-token logit to sample the first generated token from
            toks = req.prompt if req.prompt else [0]
            nxt = None
            for i, t in enumerate(toks):
                tok = jnp.zeros((self.slots, 1), jnp.int32).at[slot, 0].set(t)
                idx = list(self.pos)
                # other slots decode a dummy token at their own next position;
                # the write is overwritten by their next real token, so
                # concurrent prefill never corrupts an active slot's cache
                idx[slot] = i
                self.cache, nxt = self._decode(
                    self.params, self.cache, tok, jnp.asarray(idx, jnp.int32))
            self.pos[slot] = len(toks)
            req.generated.append(int(nxt[slot]))

    def step(self) -> List[Tuple[int, int]]:
        """One lockstep decode over all active slots; returns (rid, token)."""
        self._admit()
        if not self.active:
            return []
        # per-slot position vector: each slot decodes at its own context
        # length, so staggered admissions keep independent KV positions
        tok = jnp.zeros((self.slots, 1), jnp.int32)
        for rid, req in self.active.items():
            tok = tok.at[self.slot_of[rid], 0].set(req.generated[-1])
        self.cache, nxt = self._decode(self.params, self.cache, tok,
                                       jnp.asarray(self.pos, jnp.int32))
        out = []
        finished = []
        for rid, req in list(self.active.items()):
            slot = self.slot_of[rid]
            t = int(nxt[slot])
            req.generated.append(t)
            self.pos[slot] += 1
            out.append((rid, t))
            if req.done or self.pos[slot] >= self.max_len - 1:
                finished.append(rid)
        for rid in finished:
            slot = self.slot_of.pop(rid)
            self.active.pop(rid)
            self.free.append(slot)
            self.pos[slot] = 0
        return out

    def run_to_completion(self, max_steps: int = 10_000) -> None:
        steps = 0
        while (self.active or self.queue) and steps < max_steps:
            self.step()
            steps += 1
