"""Fault-tolerant training driver.

Production behaviours, scaled to run under test on CPU:
  * checkpoint/restart -- atomic checkpoints every N steps (async writer);
    on (re)start the driver restores the latest valid checkpoint and resumes
    from its step (data pipeline is step-indexed, so no data state is lost).
  * failure handling -- a ``FailureInjector`` (tests) or real exceptions
    trigger restart-from-checkpoint with bounded retries.
  * straggler mitigation -- per-step wall-time EWMA; steps slower than
    ``straggler_factor`` x EWMA are logged and counted, and a hook lets the
    launcher rebalance or evict (on CPU we record; on a real fleet this is
    where you would trigger hot-spare swap).
  * elastic scaling -- checkpoints are mesh-independent; ``Trainer`` accepts
    any mesh/sharding at construction, so restarting on a different device
    count reshards transparently (tested 8 -> 4 fake devices).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import store
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.training.step import init_state, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    max_restarts: int = 3
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    log_every: int = 10
    accum: int = 1


class FailureInjector:
    """Deterministic fault injection for tests: raises at given steps."""

    def __init__(self, fail_at: Optional[List[int]] = None):
        self.fail_at = set(fail_at or [])
        self.fired: set = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerStats:
    ewma: float = 0.0
    count: int = 0
    events: List[Dict[str, float]] = dataclasses.field(default_factory=list)


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        tc: TrainerConfig,
        dc: DataConfig,
        oc: Optional[adamw.OptimizerConfig] = None,
        *,
        seed: int = 0,
        shardings: Optional[Any] = None,
        donate: bool = True,
        failure_injector: Optional[FailureInjector] = None,
        on_straggler: Optional[Callable[[int, float, float], None]] = None,
    ):
        self.cfg = cfg
        self.tc = tc
        self.dc = dc
        self.oc = oc or adamw.OptimizerConfig(total_steps=tc.total_steps)
        self.seed = seed
        self.shardings = shardings
        self.failure_injector = failure_injector
        self.on_straggler = on_straggler
        self.stragglers = StragglerStats()
        self.data = SyntheticLM(cfg, dc)
        self.ckpt = store.AsyncCheckpointer(tc.checkpoint_dir,
                                            keep=tc.keep_checkpoints)
        step_fn = make_train_step(cfg, self.oc, accum=tc.accum)
        self._jit_step = jax.jit(
            step_fn, donate_argnums=(0,) if donate else ())
        self.metrics_log: List[Dict[str, float]] = []
        self.restarts = 0

    # ------------------------------------------------------------------ #

    def _fresh_state(self):
        state, _ = init_state(jax.random.PRNGKey(self.seed), self.cfg, self.oc)
        if self.shardings is not None:
            state = jax.tree.map(jax.device_put, state, self.shardings)
        return state

    def _restore_or_init(self):
        latest = store.latest_step(self.tc.checkpoint_dir)
        if latest is None:
            return self._fresh_state(), 0
        template = jax.eval_shape(
            lambda: init_state(jax.random.PRNGKey(self.seed), self.cfg,
                               self.oc)[0])
        state, extra = store.restore(
            self.tc.checkpoint_dir, template, step=latest,
            shardings=self.shardings)
        return state, int(extra["step"])

    def _track_step_time(self, step: int, dt: float) -> None:
        st = self.stragglers
        if st.ewma == 0.0:
            st.ewma = dt
            return
        if dt > self.tc.straggler_factor * st.ewma:
            st.count += 1
            st.events.append({"step": step, "dt": dt, "ewma": st.ewma})
            if self.on_straggler:
                self.on_straggler(step, dt, st.ewma)
        st.ewma = (1 - self.tc.ewma_alpha) * st.ewma + self.tc.ewma_alpha * dt

    # ------------------------------------------------------------------ #

    def run(self) -> Dict[str, Any]:
        """Train to total_steps with restart-on-failure.  Returns summary."""
        while True:
            try:
                return self._run_once()
            except Exception as exc:  # noqa: BLE001 - restart barrier
                self.restarts += 1
                if self.restarts > self.tc.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.tc.max_restarts}"
                    ) from exc
                print(f"[trainer] failure ({exc}); restart "
                      f"{self.restarts}/{self.tc.max_restarts} from latest "
                      f"checkpoint")

    def _run_once(self) -> Dict[str, Any]:
        state, start_step = self._restore_or_init()
        step = start_step
        while step < self.tc.total_steps:
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self.data.batch(step).items()}
            if self.failure_injector:
                self.failure_injector.maybe_fail(step)
            t0 = time.perf_counter()
            state, metrics = self._jit_step(state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            self._track_step_time(step, dt)
            metrics["step"] = step
            metrics["step_time_s"] = dt
            self.metrics_log.append(metrics)
            step += 1
            if step % self.tc.log_every == 0:
                print(f"[trainer] step {step}: loss={metrics['loss']:.4f} "
                      f"acc={metrics['accuracy']:.3f} {dt*1e3:.0f}ms")
            if step % self.tc.checkpoint_every == 0:
                self.ckpt.save(step, state, extra={"loss": metrics["loss"]})
        self.ckpt.save(self.tc.total_steps, state, extra={})
        self.ckpt.wait()
        return {
            "final_state": state,
            "steps": step,
            "restarts": self.restarts,
            "straggler_events": self.stragglers.count,
            "metrics": self.metrics_log,
        }
