"""Train-step construction: value_and_grad + AdamW + optional microbatching.

``make_train_step(cfg, oc, accum=1)`` returns a pure ``train_step(state,
batch) -> (state, metrics)`` suitable for ``jax.jit`` with donated state.
With ``accum > 1`` the global batch is split into microbatches accumulated
with a ``lax.scan`` (gradient accumulation: the standard memory/throughput
knob at scale).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import adamw

TrainState = Dict[str, Any]  # {"params", "opt", "rng"}


def init_state(key, cfg: ModelConfig, oc: adamw.OptimizerConfig,
               abstract: bool = False) -> Tuple[TrainState, Any]:
    """Returns (state, axes) where axes mirrors state["params"]."""
    params, axes = T.init_model(key, cfg, abstract=abstract)
    if abstract:
        opt = jax.eval_shape(lambda p: adamw.init(p, oc), params)
    else:
        opt = adamw.init(params, oc)
    return {"params": params, "opt": opt}, axes


def make_train_step(cfg: ModelConfig, oc: adamw.OptimizerConfig, accum: int = 1):
    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            T.loss_fn, has_aux=True)(params, cfg, batch)
        return loss, metrics, grads

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        params = state["params"]
        if accum > 1:
            def micro(carry, mb):
                acc_grads, acc_loss = carry
                loss, metrics, grads = grads_of(params, mb)
                acc_grads = jax.tree.map(jnp.add, acc_grads, grads)
                return (acc_grads, acc_loss + loss), metrics

            mb_batch = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                batch,
            )
            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), metrics = lax.scan(
                micro, (zero_grads, jnp.float32(0.0)), mb_batch)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum
            metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)
        else:
            loss, metrics, grads = grads_of(params, batch)

        new_params, new_opt, stats = adamw.update(grads, state["opt"], params, oc)
        metrics = dict(metrics)
        metrics.update(stats)
        metrics["total_loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
