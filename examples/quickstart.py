"""Quickstart: congruence-profile a model in under a minute (CPU).

Builds a small dense LM, compiles one train step, extracts the workload
profile from the compiled artifact, and prints the paper's three congruence
scores (ICS / HRCS / LBCS), the aggregate score, and the best-fit hardware
variant -- the whole paper pipeline end to end.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (
    TPU_V5E,
    VARIANTS,
    analyze,
    evaluate,
    profile_congruence,
    profile_from_compiled,
)
from repro.optim import adamw
from repro.training.step import init_state, make_train_step


def main() -> None:
    cfg = get_config("chatglm3-6b", smoke=True)
    oc = adamw.OptimizerConfig(warmup_steps=10, total_steps=100)

    # 1. Compile once (the expensive "place & route" step)
    state, _ = init_state(jax.random.PRNGKey(0), cfg, oc)
    batch = {
        "tokens": jnp.zeros((4, 64), jnp.int32),
        "labels": jnp.zeros((4, 64), jnp.int32),
    }
    step = make_train_step(cfg, oc)
    compiled = jax.jit(step).lower(state, batch).compile()

    # 2. Extract the workload profile (FLOPs, HBM bytes, collective bytes)
    profile = profile_from_compiled(
        "quickstart", compiled, num_devices=1,
        model_flops=6 * cfg.param_counts()[1] * batch["tokens"].size,
        tokens=batch["tokens"].size)
    print(f"profile: flops={profile.flops:.3e} hbm={profile.hbm_bytes:.3e} "
          f"collective={profile.total_collective_bytes:.3e}")

    # 3. Congruence scores (Eq. 1): idealize one subsystem at a time
    report = profile_congruence(profile, TPU_V5E)
    print(f"ICS={report.ics:.3f}  HRCS={report.hrcs:.3f}  "
          f"LBCS={report.lbcs:.3f}")
    print(f"aggregate={report.aggregate:.3f}  dominant={report.dominant}")

    # 4. Roofline terms
    rl = analyze(profile, TPU_V5E)
    print(rl.one_liner())

    # 5. DSE across hardware variants (Table I, one row)
    table = evaluate([profile])
    print("best-fit variant:", table.best_fit(profile.name))
    for v in table.variants:
        print(f"  {v}: aggregate={table.cell(profile.name, v).aggregate:.3f}")


if __name__ == "__main__":
    main()
