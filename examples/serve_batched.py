"""Serve a small model with batched requests (continuous batching).

Demonstrates the serving substrate: fixed decode slots, slot recycling,
prefill-then-decode, greedy sampling -- the dataflow the decode_32k /
long_500k dry-run shapes exercise at production scale.

Run:  PYTHONPATH=src python examples/serve_batched.py [--arch ID]
"""

import argparse
import time

import jax

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving.engine import BatchedEngine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="falcon-mamba-7b",
                    help="SSM decodes O(1)/token -- nice on CPU")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    engine = BatchedEngine(params, cfg, slots=args.slots, max_len=64)

    prompts = [[(7 * i + j) % cfg.vocab_size for j in range(4)]
               for i in range(args.requests)]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=args.new_tokens)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)

    t0 = time.perf_counter()
    steps = 0
    while engine.active or engine.queue:
        engine.step()
        steps += 1
        if steps > 10_000:
            raise RuntimeError("engine did not drain")
    dt = time.perf_counter() - t0

    total_tokens = sum(len(r.generated) for r in reqs)
    print(f"served {len(reqs)} requests / {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / dt:.1f} tok/s, {args.slots} slots)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt={r.prompt} -> {r.generated}")
    assert all(len(r.generated) >= r.max_new_tokens for r in reqs)


if __name__ == "__main__":
    main()
