"""Co-design DSE over the dry-run artifacts (the paper's §III workflow).

Loads the compiled-cell profiles (or synthetic stand-ins), runs the full
Table-I sweep, prints radar rows (Fig. 3) and pairs each application with
its best-fit architecture variant, plus a bottleneck-shift demonstration
(Fig. 2): what happens to the congruence profile when you fix the dominant
subsystem.

All scoring flows through the backend-agnostic kernel layer
(``repro.core.kernels_xp``): pass ``backend="jax"`` to ``evaluate`` /
``run_sweep`` (or set ``REPRO_SWEEP_BACKEND=jax``) to jit the same math on
device for large populations.  The final section shows the two co-design
modes that build on it:

  * multi-objective sweep -- ``run_sweep(...).pareto_front_3d()`` ranks
    sampled designs on (aggregate congruence, silicon area, dynamic power)
    via the configurable ``CostModel``;
  * gradient descent -- ``grad_codesign`` differentiates the scalarized
    objective through the jitted kernels (``jax.grad`` on machine
    log-rates) and walks the named seeds downhill.

Run:  PYTHONPATH=src:. python examples/dse_codesign.py
(after ``python -m repro.launch.dryrun`` for real artifacts)
"""

import sys

sys.path.insert(0, ".")  # for benchmarks.common when run from repo root

from benchmarks import common  # noqa: E402
from repro.core import (  # noqa: E402
    TPU_V5E,
    VARIANTS,
    evaluate,
    grad_codesign,
    profile_congruence,
    run_sweep,
)


def main() -> None:
    profiles, synth = common.profiles_or_synthetic()
    if synth:
        print("(no dry-run artifacts found; using synthetic profiles)")
    suites = common.suites_of(profiles)

    table = evaluate(profiles, suites=suites, clamp=True)

    print("== Fig. 3: congruence radar (baseline variant) ==")
    for app in table.apps:
        rep = table.cell(app, "baseline").report
        bars = {k: "#" * int(v * 20) for k, v in rep.radar_row().items()}
        print(f"{app:45s} ICS {bars['ICS']:<20s} HRCS {bars['HRCS']:<20s} "
              f"LBCS {bars['LBCS']:<20s}")

    print("\n== Table I: best-fit architecture per application ==")
    for app in table.apps:
        cells = " ".join(f"{v}={table.cell(app, v).aggregate:.3f}"
                         for v in table.variants)
        print(f"{app:45s} {cells}  -> {table.best_fit(app)}")
    for suite in suites:
        print(f"[{suite}] mean best fit: {table.suite_best_fit(suite)}")
    print(f"[all] overall best fit: {table.overall_best_fit()}")

    print("\n== Fig. 2: bottleneck shift under co-design ==")
    p = profiles[0]
    rep = profile_congruence(p, TPU_V5E, clamp=True)
    print(f"{p.name}: dominant={rep.dominant} scores={ {k: round(v,3) for k,v in rep.scores.items()} }")
    # co-design response: idealize the dominant subsystem's hardware
    from repro.core import SCORE_NAMES, Subsystem
    inv = {v: k for k, v in SCORE_NAMES.items()}
    fixed = TPU_V5E.with_scales(**{inv[rep.dominant].value: 0.25})
    rep2 = profile_congruence(p, fixed, clamp=True)
    print(f"  after 4x faster {inv[rep.dominant].value}: "
          f"dominant={rep2.dominant} scores={ {k: round(v,3) for k,v in rep2.scores.items()} }")

    print("\n== multi-objective sweep: congruence x area x power ==")
    res = run_sweep(profiles, n=512, include_named=VARIANTS)
    area, power, agg = res.area(), res.power(), res.aggregate_mean()
    for i in res.pareto_front_3d()[:8]:
        print(f"{res.machines.names[i]:12s} aggregate={agg[i]:.3f} "
              f"area={area[i]:.3f} power={power[i]:.3f}")

    print("\n== gradient co-design (jax.grad through the shared kernels) ==")
    from repro.core.sweep import MachineBatch
    cd = grad_codesign(profiles, MachineBatch.from_models(VARIANTS), steps=60)
    for n, js, jf in zip(cd.names, cd.objective_seed, cd.objective_final):
        print(f"{n:12s} objective {js:.4f} -> {jf:.4f}")
    best = cd.best_model()
    print(f"best: {best.name} peak_flops={best.peak_flops:.3e} "
          f"hbm_bw={best.hbm_bw:.3e} ici_bw={best.ici_bw:.3e}")

    print("\n== constrained co-design: stay inside the silicon budget ==")
    # Warm-start descent from the sweep's Pareto survivors and keep
    # CostModel.area(m) <= 1.0 (the reference chip) -- docs/codesign.md
    # is the full guide.
    from repro.core import constrained_codesign
    cc = constrained_codesign(profiles, res.seed_codesign(k=4),
                              area_budget=1.0, steps=60)
    for n, jf, a, ok in zip(cc.names, cc.objective_final, cc.area_final,
                            cc.feasible):
        print(f"{n:12s} objective={jf:.4f} area={a:.3f} "
              f"{'feasible' if ok else 'INFEASIBLE'}")
    cbest = cc.best_model()
    print(f"best feasible: {cbest.name} area="
          f"{cc.area_final[cc.best]:.3f} <= budget 1.0")


if __name__ == "__main__":
    main()
