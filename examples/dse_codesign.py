"""Co-design DSE over the dry-run artifacts (the paper's §III workflow).

Loads the compiled-cell profiles (or synthetic stand-ins), runs the full
Table-I sweep, prints radar rows (Fig. 3) and pairs each application with
its best-fit architecture variant, plus a bottleneck-shift demonstration
(Fig. 2): what happens to the congruence profile when you fix the dominant
subsystem.

Run:  PYTHONPATH=src:. python examples/dse_codesign.py
(after ``python -m repro.launch.dryrun`` for real artifacts)
"""

import sys

sys.path.insert(0, ".")  # for benchmarks.common when run from repo root

from benchmarks import common  # noqa: E402
from repro.core import TPU_V5E, evaluate, profile_congruence  # noqa: E402


def main() -> None:
    profiles, synth = common.profiles_or_synthetic()
    if synth:
        print("(no dry-run artifacts found; using synthetic profiles)")
    suites = common.suites_of(profiles)

    table = evaluate(profiles, suites=suites, clamp=True)

    print("== Fig. 3: congruence radar (baseline variant) ==")
    for app in table.apps:
        rep = table.cell(app, "baseline").report
        bars = {k: "#" * int(v * 20) for k, v in rep.radar_row().items()}
        print(f"{app:45s} ICS {bars['ICS']:<20s} HRCS {bars['HRCS']:<20s} "
              f"LBCS {bars['LBCS']:<20s}")

    print("\n== Table I: best-fit architecture per application ==")
    for app in table.apps:
        cells = " ".join(f"{v}={table.cell(app, v).aggregate:.3f}"
                         for v in table.variants)
        print(f"{app:45s} {cells}  -> {table.best_fit(app)}")
    for suite in suites:
        print(f"[{suite}] mean best fit: {table.suite_best_fit(suite)}")
    print(f"[all] overall best fit: {table.overall_best_fit()}")

    print("\n== Fig. 2: bottleneck shift under co-design ==")
    p = profiles[0]
    rep = profile_congruence(p, TPU_V5E, clamp=True)
    print(f"{p.name}: dominant={rep.dominant} scores={ {k: round(v,3) for k,v in rep.scores.items()} }")
    # co-design response: idealize the dominant subsystem's hardware
    from repro.core import SCORE_NAMES, Subsystem
    inv = {v: k for k, v in SCORE_NAMES.items()}
    fixed = TPU_V5E.with_scales(**{inv[rep.dominant].value: 0.25})
    rep2 = profile_congruence(p, fixed, clamp=True)
    print(f"  after 4x faster {inv[rep.dominant].value}: "
          f"dominant={rep2.dominant} scores={ {k: round(v,3) for k,v in rep2.scores.items()} }")


if __name__ == "__main__":
    main()
