"""End-to-end training driver: train a small LM for a few hundred steps on
CPU with the full production stack -- synthetic data pipeline, AdamW with
warmup-cosine, fault-tolerant trainer with async checkpointing + straggler
monitoring, and a post-run congruence profile of the training step.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch ID]
      [--params-100m]   (scale the model to ~100M params; slower)
"""

import argparse
import shutil

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import TPU_V5E, profile_congruence, profile_from_compiled
from repro.data.pipeline import DataConfig
from repro.optim import adamw
from repro.training.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--params-100m", action="store_true",
                    help="~100M-param model (CPU: expect ~1 s/step)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if args.params_100m:
        cfg = cfg.replace(n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
                          d_ff=2048, vocab_size=65024)
    total, active = cfg.param_counts()
    print(f"model: {cfg.name}  params={total/1e6:.1f}M")

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    tc = TrainerConfig(total_steps=args.steps, checkpoint_every=100,
                       checkpoint_dir=args.ckpt_dir, log_every=25)
    dc = DataConfig(seq_len=args.seq_len, global_batch=args.batch)
    oc = adamw.OptimizerConfig(peak_lr=1e-3, warmup_steps=30,
                               total_steps=args.steps)
    trainer = Trainer(cfg, tc, dc, oc)
    out = trainer.run()

    losses = [m["loss"] for m in out["metrics"]]
    print(f"\nloss: {losses[0]:.4f} -> {losses[-1]:.4f} over {out['steps']} "
          f"steps ({out['restarts']} restarts, "
          f"{out['straggler_events']} straggler events)")
    assert losses[-1] < losses[0], "training did not reduce loss"

    # profile the compiled step (paper pipeline on the real artifact)
    state = out["final_state"]
    batch = {k: jnp.asarray(v) for k, v in trainer.data.batch(0).items()}
    from repro.training.step import make_train_step
    compiled = jax.jit(make_train_step(cfg, oc)).lower(state, batch).compile()
    profile = profile_from_compiled(
        "train_lm", compiled, num_devices=1,
        model_flops=6 * active * batch["tokens"].size,
        tokens=batch["tokens"].size)
    rep = profile_congruence(profile, TPU_V5E)
    print(f"congruence: ICS={rep.ics:.3f} HRCS={rep.hrcs:.3f} "
          f"LBCS={rep.lbcs:.3f} -> dominant {rep.dominant}")


if __name__ == "__main__":
    main()
